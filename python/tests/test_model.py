"""L2 model-graph tests: physics, shapes, determinism, distribution moments."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import common as cm

U32 = jnp.uint32
N = 4096


def params(gseed=0, step=0):
    lo, hi = cm.split_seed(gseed)
    return jnp.asarray([int(lo), int(hi), step, 0], U32)


def test_brownian_init_grid():
    pv = np.asarray(model.brownian_init(N))
    assert pv.shape == (N, 4)
    assert (pv[:, 2:] == 0).all()
    # All particles on distinct grid points.
    pts = {(x, y) for x, y in pv[:, :2]}
    assert len(pts) == N


def test_brownian_step_shapes_and_determinism():
    pv = model.brownian_init(N)
    a = np.asarray(model.brownian_step(pv, params(0, 0), N))
    b = np.asarray(model.brownian_step(pv, params(0, 0), N))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(model.brownian_step(pv, params(0, 1), N))
    assert (a != c).any()


def test_brownian_step_physics():
    """Drag shrinks velocity; kick is bounded by sqrt(dt); positions follow."""
    pv = jnp.concatenate(
        [jnp.zeros((N, 2), jnp.float64), jnp.full((N, 2), 10.0, jnp.float64)], axis=1
    )
    out = np.asarray(model.brownian_step(pv, params(0, 0), N))
    sqrt_dt = np.sqrt(model.DT)
    drag_v = 10.0 - (model.GAMMA / model.MASS) * 10.0 * model.DT
    assert np.all(np.abs(out[:, 2] - drag_v) <= sqrt_dt + 1e-12)
    assert np.all(np.abs(out[:, 3] - drag_v) <= sqrt_dt + 1e-12)
    np.testing.assert_allclose(out[:, 0], out[:, 2] * model.DT, rtol=1e-12)


def test_brownian_kick_is_zero_mean_uniform():
    pv = jnp.zeros((N, 4), jnp.float64)
    out = np.asarray(model.brownian_step(pv, params(123, 0), N))
    kick = out[:, 2] / np.sqrt(model.DT)  # in [-1, 1)
    assert abs(kick.mean()) < 0.05
    np.testing.assert_allclose(kick.var(), 1.0 / 3.0, rtol=0.1)  # var of U[-1,1]
    assert kick.min() >= -1.0 and kick.max() < 1.0


def test_brownian_matches_fig1_stream_contract():
    """Particle i's kick == draw_double2 of stream (seed=i^gseed, ctr=step)."""
    from compile.kernels import ref

    pv = jnp.zeros((N, 4), jnp.float64)
    gseed, step = 0xABCDEF0123456789, 17
    out = np.asarray(model.brownian_step(pv, params(gseed, step), N))
    sqrt_dt = np.sqrt(model.DT)
    for i in (0, 1, 777, N - 1):
        w = np.asarray(ref.philox4x32_stream(i ^ gseed, step, 4))
        r1 = ((int(w[0]) << 32 | int(w[1])) >> 11) * 2.0**-53
        r2 = ((int(w[2]) << 32 | int(w[3])) >> 11) * 2.0**-53
        np.testing.assert_allclose(out[i, 2], (r1 * 2 - 1) * sqrt_dt, rtol=1e-12)
        np.testing.assert_allclose(out[i, 3], (r2 * 2 - 1) * sqrt_dt, rtol=1e-12)


def test_stateful_state_init_layout():
    st = np.asarray(model.curand_state_init(params(42, 0), N))
    assert st.shape == (N, 16) and st.dtype == np.uint32
    assert (st[:, 0] == np.arange(N)).all()  # subsequence = pid
    assert (st[:, 4] == np.uint32(42)).all()  # key lo
    assert st.nbytes == 64 * N  # the paper's 64 MB per 1M particles


def test_stateful_step_advances_counter_and_matches_core():
    pv = jnp.zeros((N, 4), jnp.float64)
    st = model.curand_state_init(params(0, 0), N)
    out, st2 = model.brownian_step_stateful(pv, st, N)
    out, st2 = np.asarray(out), np.asarray(st2)
    assert (st2[:, 0] == np.asarray(st)[:, 0] + 1).all()
    # Same Philox core: particle i, state ctr=[i,0,0,0], key=[0,0] ==
    # stream (seed=i? no: raw core) — check via raw oracle.
    from compile.kernels import ref

    i = 99
    w = np.asarray(
        ref.philox4x32(
            jnp.asarray([[i, 0, 0, 0]], U32), jnp.asarray([[0, 0]], U32)
        )
    ).reshape(-1)
    r1 = ((int(w[0]) << 32 | int(w[1])) >> 11) * 2.0**-53
    np.testing.assert_allclose(out[i, 2], (r1 * 2 - 1) * np.sqrt(model.DT), rtol=1e-12)
    # Buffered output words stored back (state words 6..10).
    np.testing.assert_array_equal(st2[i, 6:10], w)


def test_stateful_counter_carry():
    """128-bit counter increment carries across words."""
    st = jnp.asarray([[0xFFFFFFFF, 0xFFFFFFFF, 0xFFFFFFFF, 7, 0, 0] + [0] * 10], U32)
    pv = jnp.zeros((1, 4), jnp.float64)
    _, st2 = model.brownian_step_stateful(pv, st, 1)
    st2 = np.asarray(st2)
    assert list(st2[0, :4]) == [0, 0, 0, 8]


def test_split_stateful_graphs_match_combined():
    """The chainable split pair (pos + state-update) must reproduce the
    combined stateful graph: identical positions, identical counters."""
    pv = jnp.zeros((N, 4), jnp.float64)
    st = model.curand_state_init(params(7, 0), N)
    out_c, st_c = model.brownian_step_stateful(pv, st, N)
    out_s = model.brownian_step_stateful_pos(pv, st, N)
    st_s = model.curand_state_update(st, N)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_s))
    # Counters and key identical; the split path does not materialize the
    # cuRAND out-buffer words (6..10) — documented deviation.
    np.testing.assert_array_equal(np.asarray(st_c)[:, :6], np.asarray(st_s)[:, :6])


def test_split_stateful_multi_step_trajectory():
    pv = jnp.zeros((N, 4), jnp.float64)
    st = model.curand_state_init(params(3, 0), N)
    pv_c, st_c = pv, st
    pv_s, st_s = pv, st
    for _ in range(3):
        pv_c, st_c = model.brownian_step_stateful(pv_c, st_c, N)
        pv_s2 = model.brownian_step_stateful_pos(pv_s, st_s, N)
        st_s = model.curand_state_update(st_s, N)
        pv_s = pv_s2
    np.testing.assert_array_equal(np.asarray(pv_c), np.asarray(pv_s))


def test_uniform_f64_block_bounds_and_mean():
    u = np.asarray(model.uniform_f64_block(params(7, 0), 32768))
    assert u.min() >= 0.0 and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.01


def test_normal_block_moments():
    z = np.asarray(model.normal_f64_block(params(7, 0), 32768))
    assert abs(z.mean()) < 0.03
    np.testing.assert_allclose(z.std(), 1.0, rtol=0.03)


@pytest.mark.parametrize("gen", ["philox", "threefry", "squares", "tyche"])
def test_uniform_u32_block_all_generators(gen):
    u = np.asarray(model.uniform_u32_block(params(3, 1), 4096, gen=gen))
    assert u.shape == (4096,) and u.dtype == np.uint32
    # Crude sanity: at least 99% distinct values, mean near 2^31.
    assert len(np.unique(u)) > 4050
    assert abs(u.astype(np.float64).mean() / 2**31 - 1.0) < 0.05
