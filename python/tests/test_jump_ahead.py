"""Cross-layer KATs for the jump-ahead contract (`CounterRng::advance`).

The Rust engines implement `advance(n)` / `jump()` as O(1) counter
arithmetic: word position `p` of stream `(seed, ctr)` lives in block
`p // W` at lane `p % W`, with 4x32 block ids widened past u32 as
`[j_lo, ctr, j_hi, 0]`. These tests pin that address arithmetic against
the jnp oracle at the exact positions the Rust unit suite pins
(`rust/src/core/{philox,threefry,squares,tyche}.rs` and
`rust/src/stats/interstream.rs` assert the same hex literals), so a
drifted counter layout on either layer breaks one side's KAT.

Strides covered: the per-engine `jump()` stride (2^33 for the 4x32
engines, 2^16 for the 2x32/Squares engines), a beyond-2^32-words
position (the u64 widening), the short-period wrap (2x32: 2^33 words,
Squares: 2^32 words), and Tyche's O(n) stepping `advance`.
"""

import numpy as np

from compile.kernels import common as cm
from compile.kernels import ref

U32 = np.uint32

# One literal per claim; the Rust side pins the identical values.
PHILOX_S7_C1_JUMP_2_33 = 0x3A294131  # block [0x80000000, 1, 0, 0] word 0
PHILOX_S7_C1_WORD_2_34P2 = 0x275A0C0F  # block [0, 1, 1, 0] word 2
PHILOX_S7_C1_WORD_9 = 0x498FF58B
PHILOX2_S7_C1_JUMP_2_16 = 0x44EF38AA  # block [0x8000, 1] word 0
PHILOX2_S7_C1_WORD_5 = 0xB92B6CAC  # == word 2^33 + 5 (period wrap)
THREEFRY_S2_C6_JUMP_2_33 = 0xDFC693FF  # block [0x80000000, 6, 0, 0] word 0
THREEFRY_S2_C6_WORD_2_34 = 0x31ADC0A0  # block [0, 6, 1, 0] word 0
THREEFRY2_S5_C3_JUMP_2_16 = 0xFB1254E1  # block [0x8000, 3] word 0
SQUARES_S7_C1_JUMP_2_16 = 0x853F0F97
SQUARES_S7_C1_WORD_3 = 0x7900D050  # == word 2^32 + 3 (period wrap)
TYCHE_S7_C1_WORD_5 = 0x6912D082
TYCHE_I_S7_C1_WORD_5 = 0xC1170F7E

# InterStream<Philox> over root(7), K = 4 children, stride 1: round q
# emits word q of child s = derive_child_seed(7, 0, s) in s order.
INTERSTREAM_PHILOX_ROOT7_K4_ROUND0 = [0xEF16B664, 0xF1282995, 0x89A68AC1, 0x079F41FA]
INTERSTREAM_PHILOX_ROOT7_K4_ROUND1_PREFIX = [0x2EDDD51C, 0xB2BDD7E0]


def philox_block(j, ctr, seed):
    """Philox4x32 block at 64-bit block id j — the widened counter layout."""
    blk = np.array([j & 0xFFFF_FFFF, ctr, j >> 32, 0], U32)
    return ref.philox4x32(blk, np.array(cm.split_seed(seed), U32))


def threefry_block(j, ctr, seed):
    blk = np.array([j & 0xFFFF_FFFF, ctr, j >> 32, 0], U32)
    lo, hi = cm.split_seed(seed)
    return ref.threefry4x32(blk, np.array([lo, hi, 0, 0], U32))


def test_philox_jump_kats():
    # jump() = advance(2^33 words) = 2^31 blocks.
    assert int(philox_block(1 << 31, 1, 7)[0]) == PHILOX_S7_C1_JUMP_2_33
    # Past 2^32 words: position 2^34 + 2 -> block 2^32 (j_hi = 1), lane 2.
    assert int(philox_block(1 << 32, 1, 7)[2]) == PHILOX_S7_C1_WORD_2_34P2
    # Small advance agrees with the sequential stream oracle.
    assert int(ref.philox4x32_stream(7, 1, 10)[9]) == PHILOX_S7_C1_WORD_9
    # The widened layout is bit-identical to the legacy [j, ctr, 0, 0]
    # layout for every block id below 2^32 (zero stream drift).
    legacy = ref.philox4x32_stream(7, 1, 8)
    for p in range(8):
        assert int(philox_block(p // 4, 1, 7)[p % 4]) == int(legacy[p])


def test_threefry_jump_kats():
    assert int(threefry_block(1 << 31, 6, 2)[0]) == THREEFRY_S2_C6_JUMP_2_33
    assert int(threefry_block(1 << 32, 6, 2)[0]) == THREEFRY_S2_C6_WORD_2_34
    legacy = ref.threefry4x32_stream(2, 6, 8)
    for p in range(8):
        assert int(threefry_block(p // 4, 6, 2)[p % 4]) == int(legacy[p])


def test_2x32_jump_and_period_wrap_kats():
    # jump() = advance(2^16 words) = block 2^15, lane 0.
    got = ref.philox2x32_stream(7, 1, (1 << 16) + 1)
    assert int(got[1 << 16]) == PHILOX2_S7_C1_JUMP_2_16
    # The 2x32 stream period is 2^33 words; advance wraps mod it, so
    # word 2^33 + 5 must equal word 5 — the Rust advance() KAT target.
    assert int(got[5]) == PHILOX2_S7_C1_WORD_5
    lo, hi = cm.split_seed(5)
    tf = ref.threefry2x32(np.array([0x8000, 3], U32), np.array([lo, hi], U32))
    assert int(tf[0]) == THREEFRY2_S5_C3_JUMP_2_16


def test_squares_jump_and_wrap_kats():
    key = np.uint64(cm.squares_key(7))
    c = np.uint64((1 << 32) | (1 << 16))  # ctr 1, low-half position 2^16
    assert int(ref.squares32(c, key)) == SQUARES_S7_C1_JUMP_2_16
    # Squares' per-stream period is 2^32 words (the low counter half);
    # word 2^32 + 3 wraps to word 3.
    assert int(ref.squares_stream(7, 1, 4)[3]) == SQUARES_S7_C1_WORD_3


def test_tyche_advance_is_exact_stepping():
    # Tyche has no O(1) skip; advance(n) is n mixes, so word 5 after
    # advance(5) is just the sequential stream's word 5.
    assert int(ref.tyche_stream_api(7, 1, 6)[5]) == TYCHE_S7_C1_WORD_5
    assert int(ref.tyche_stream_api(7, 1, 6, inverse=True)[5]) == TYCHE_I_S7_C1_WORD_5


def test_interstream_interleaving_kat():
    # The inter-stream battery's merge order: round q emits word q of
    # child s for s = 0..K-1. Mirrors interstream.rs's KAT test.
    k = 4
    children = [cm.derive_child_seed(7, 0, s) for s in range(k)]
    round0 = [int(ref.philox4x32_stream(cs, 0, 1)[0]) for cs in children]
    assert round0 == INTERSTREAM_PHILOX_ROOT7_K4_ROUND0
    round1 = [int(ref.philox4x32_stream(cs, 0, 2)[1]) for cs in children[:2]]
    assert round1 == INTERSTREAM_PHILOX_ROOT7_K4_ROUND1_PREFIX
