"""CPython `ctypes` consumer of the C ABI (`libopenrand_ffi.so`).

The third language of the three-way bitwise agreement actually *loads
the shared library* here: the same KAT table that `rust/src/selftest.rs`
asserts natively and `test_ffi_vectors.py` derives from the Python
oracle is replayed through `ctypes` against the built cdylib — engine
word tables, the normative u64/f64/f32 conversions, key derivation, the
bulk fills, and the typed error codes of `include/openrand.h`.

Self-skips when the cdylib is not built (fresh checkout / no Rust
toolchain); point `OPENRAND_FFI_LIB` at the library to force a
particular build. Build with::

    cargo build --release -p openrand_ffi
"""

import ctypes
import os
import struct
from pathlib import Path

import pytest

from test_ffi_vectors import (
    CHILD_SEED_R7_C3,
    CHILD_STREAM_F64_BITS,
    CHILD_STREAM_WORDS,
    ENGINE_WORDS_S7_C1,
    PHILOX_S7_C1_F32_BITS,
    PHILOX_S7_C1_F64_BITS,
    PHILOX_S7_C1_U64,
)

OK, ERR_NULL, ERR_BAD_GENERATOR, ERR_EMPTY_RANGE, ERR_NO_JUMP = 0, 1, 2, 3, 4

_ROOT = Path(__file__).resolve().parents[2]


def _find_library():
    override = os.environ.get("OPENRAND_FFI_LIB")
    if override:
        return Path(override)
    candidates = [
        _ROOT / "target" / profile / "libopenrand_ffi.so"
        for profile in ("release", "debug")
    ] + [
        _ROOT / "ffi" / "target" / profile / "libopenrand_ffi.so"
        for profile in ("release", "debug")
    ]
    for path in candidates:
        if path.exists():
            return path
    return None


_LIB_PATH = _find_library()
if _LIB_PATH is None or not _LIB_PATH.exists():
    pytest.skip(
        "libopenrand_ffi.so not built (cargo build --release -p openrand_ffi)",
        allow_module_level=True,
    )


def _bind(lib):
    """Declare every prototype exactly as `include/openrand.h` spells it."""
    h = ctypes.c_void_p  # opaque openrand_engine* / openrand_key*
    sigs = {
        "openrand_version": (ctypes.c_char_p, []),
        "openrand_strerror": (ctypes.c_char_p, [ctypes.c_int]),
        "openrand_selftest": (ctypes.c_int, []),
        "openrand_create": (
            ctypes.c_int,
            [ctypes.c_char_p, ctypes.c_uint64, ctypes.c_uint32, ctypes.POINTER(h)],
        ),
        "openrand_create_keyed": (ctypes.c_int, [ctypes.c_char_p, h, ctypes.POINTER(h)]),
        "openrand_destroy": (None, [h]),
        "openrand_next_u32": (ctypes.c_int, [h, ctypes.POINTER(ctypes.c_uint32)]),
        "openrand_next_u64": (ctypes.c_int, [h, ctypes.POINTER(ctypes.c_uint64)]),
        "openrand_uniform_f32": (ctypes.c_int, [h, ctypes.POINTER(ctypes.c_float)]),
        "openrand_uniform_f64": (ctypes.c_int, [h, ctypes.POINTER(ctypes.c_double)]),
        "openrand_range_u32": (
            ctypes.c_int,
            [h, ctypes.c_uint32, ctypes.POINTER(ctypes.c_uint32)],
        ),
        "openrand_fill_u32": (
            ctypes.c_int,
            [h, ctypes.POINTER(ctypes.c_uint32), ctypes.c_size_t],
        ),
        "openrand_fill_f64": (
            ctypes.c_int,
            [h, ctypes.POINTER(ctypes.c_double), ctypes.c_size_t],
        ),
        "openrand_advance": (ctypes.c_int, [h, ctypes.c_uint64]),
        "openrand_set_position": (ctypes.c_int, [h, ctypes.c_uint64]),
        "openrand_jump": (ctypes.c_int, [h]),
        "openrand_key_root": (ctypes.c_int, [ctypes.c_uint64, ctypes.POINTER(h)]),
        "openrand_key_raw": (
            ctypes.c_int,
            [ctypes.c_uint64, ctypes.c_uint32, ctypes.POINTER(h)],
        ),
        "openrand_key_child": (ctypes.c_int, [h, ctypes.c_uint64, ctypes.POINTER(h)]),
        "openrand_key_epoch": (ctypes.c_int, [h, ctypes.c_uint32, ctypes.POINTER(h)]),
        "openrand_key_seed": (ctypes.c_int, [h, ctypes.POINTER(ctypes.c_uint64)]),
        "openrand_key_ctr": (ctypes.c_int, [h, ctypes.POINTER(ctypes.c_uint32)]),
        "openrand_key_free": (None, [h]),
    }
    for name, (restype, argtypes) in sigs.items():
        fn = getattr(lib, name)
        fn.restype = restype
        fn.argtypes = argtypes
    return lib


LIB = _bind(ctypes.CDLL(str(_LIB_PATH)))


class Engine:
    """RAII wrapper so a failing assert never leaks a handle."""

    def __init__(self, tag, seed, ctr):
        self.h = ctypes.c_void_p()
        rc = LIB.openrand_create(tag.encode(), seed, ctr, ctypes.byref(self.h))
        assert rc == OK, f"openrand_create({tag!r}) -> {rc}"

    @classmethod
    def keyed(cls, tag, key):
        self = cls.__new__(cls)
        self.h = ctypes.c_void_p()
        rc = LIB.openrand_create_keyed(tag.encode(), key.h, ctypes.byref(self.h))
        assert rc == OK, f"openrand_create_keyed({tag!r}) -> {rc}"
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        LIB.openrand_destroy(self.h)

    def next_u32(self):
        out = ctypes.c_uint32()
        assert LIB.openrand_next_u32(self.h, ctypes.byref(out)) == OK
        return out.value

    def next_u64(self):
        out = ctypes.c_uint64()
        assert LIB.openrand_next_u64(self.h, ctypes.byref(out)) == OK
        return out.value

    def uniform_f32(self):
        out = ctypes.c_float()
        assert LIB.openrand_uniform_f32(self.h, ctypes.byref(out)) == OK
        return out.value

    def uniform_f64(self):
        out = ctypes.c_double()
        assert LIB.openrand_uniform_f64(self.h, ctypes.byref(out)) == OK
        return out.value


class Key:
    def __init__(self, handle):
        self.h = handle

    @classmethod
    def root(cls, seed):
        h = ctypes.c_void_p()
        assert LIB.openrand_key_root(seed, ctypes.byref(h)) == OK
        return cls(h)

    def child(self, child_id):
        h = ctypes.c_void_p()
        assert LIB.openrand_key_child(self.h, child_id, ctypes.byref(h)) == OK
        return Key(h)

    def epoch(self, epoch):
        h = ctypes.c_void_p()
        assert LIB.openrand_key_epoch(self.h, epoch, ctypes.byref(h)) == OK
        return Key(h)

    def seed(self):
        out = ctypes.c_uint64()
        assert LIB.openrand_key_seed(self.h, ctypes.byref(out)) == OK
        return out.value

    def ctr(self):
        out = ctypes.c_uint32()
        assert LIB.openrand_key_ctr(self.h, ctypes.byref(out)) == OK
        return out.value

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        LIB.openrand_key_free(self.h)


def f32_bits(v):
    return struct.unpack("<I", struct.pack("<f", v))[0]


def f64_bits(v):
    return struct.unpack("<Q", struct.pack("<d", v))[0]


def test_version_strerror_and_selftest():
    assert LIB.openrand_version().decode().startswith("openrand_ffi")
    assert LIB.openrand_strerror(OK) == b"ok"
    # Unknown codes still return a static string, never NULL.
    assert LIB.openrand_strerror(999)
    # The library's built-in KAT battery agrees with its own pins.
    assert LIB.openrand_selftest() == OK


def test_engine_word_tables_match_shared_vectors():
    for tag, want in ENGINE_WORDS_S7_C1.items():
        with Engine(tag, 7, 1) as e:
            got = [e.next_u32() for _ in range(len(want))]
        assert got == want, tag


def test_conversion_bits_match_shared_vectors():
    with Engine("philox", 7, 1) as e:
        assert e.next_u64() == PHILOX_S7_C1_U64
    with Engine("philox", 7, 1) as e:
        assert f64_bits(e.uniform_f64()) == PHILOX_S7_C1_F64_BITS
    with Engine("philox", 7, 1) as e:
        assert f32_bits(e.uniform_f32()) == PHILOX_S7_C1_F32_BITS


def test_key_derivation_matches_shared_vectors():
    with Key.root(7) as root, root.child(3) as child, child.epoch(1) as key:
        assert key.seed() == CHILD_SEED_R7_C3
        assert key.ctr() == 1
        with Engine.keyed("philox", key) as e:
            assert [e.next_u32() for _ in range(2)] == CHILD_STREAM_WORDS
        with Engine.keyed("philox", key) as e:
            assert f64_bits(e.uniform_f64()) == CHILD_STREAM_F64_BITS


def test_fill_matches_scalar_draws():
    n = 257
    with Engine("threefry", 11, 4) as e:
        want = [e.next_u32() for _ in range(n)]
    with Engine("threefry", 11, 4) as e:
        buf = (ctypes.c_uint32 * n)()
        assert LIB.openrand_fill_u32(e.h, buf, n) == OK
        assert list(buf) == want
    with Engine("squares", 3, 9) as e:
        want_f = [e.uniform_f64() for _ in range(40)]
    with Engine("squares", 3, 9) as e:
        fbuf = (ctypes.c_double * 40)()
        assert LIB.openrand_fill_f64(e.h, fbuf, 40) == OK
        assert [f64_bits(v) for v in fbuf] == [f64_bits(v) for v in want_f]


def test_advance_set_position_and_jump():
    with Engine("philox", 5, 2) as e:
        words = [e.next_u32() for _ in range(8)]
    with Engine("philox", 5, 2) as e:
        assert LIB.openrand_advance(e.h, 5) == OK
        assert e.next_u32() == words[5]
        assert LIB.openrand_set_position(e.h, 3) == OK
        assert e.next_u32() == words[3]
    # O(1) jump exists on the counter engines, not on tyche/tyche_i.
    with Engine("philox", 5, 2) as e:
        assert LIB.openrand_jump(e.h) == OK
    for tag in ("tyche", "tyche_i"):
        with Engine(tag, 5, 2) as e:
            assert LIB.openrand_jump(e.h) == ERR_NO_JUMP


def test_error_codes_match_header_contract():
    out = ctypes.c_void_p()
    assert LIB.openrand_create(b"not_an_engine", 0, 0, ctypes.byref(out)) == ERR_BAD_GENERATOR
    assert LIB.openrand_create(None, 0, 0, ctypes.byref(out)) == ERR_NULL
    assert LIB.openrand_create(b"philox", 0, 0, None) == ERR_NULL
    with Engine("philox", 1, 0) as e:
        got = ctypes.c_uint32()
        assert LIB.openrand_range_u32(e.h, 0, ctypes.byref(got)) == ERR_EMPTY_RANGE
        # bound=1 can only ever produce 0.
        assert LIB.openrand_range_u32(e.h, 1, ctypes.byref(got)) == OK
        assert got.value == 0
    w = ctypes.c_uint32()
    assert LIB.openrand_next_u32(None, ctypes.byref(w)) == ERR_NULL
    # NULL destroy / key_free are documented no-ops.
    LIB.openrand_destroy(None)
    LIB.openrand_key_free(None)
