"""AOT pipeline tests: lowering determinism, manifest integrity, and the
HLO-text invariants the Rust loader depends on."""

import json
import os
import subprocess
import sys

import jax
import pytest

from compile import aot, model


def test_manifest_signature_strings():
    assert aot._sig((jax.ShapeDtypeStruct((4,), "uint32"),)) == "uint32[4]"
    assert (
        aot._sig(
            (
                jax.ShapeDtypeStruct((8, 4), "float64"),
                jax.ShapeDtypeStruct((4,), "uint32"),
            )
        )
        == "float64[8,4];uint32[4]"
    )


def test_lowering_is_deterministic():
    graphs = model.aot_graphs(sizes_block=(65536,), sizes_sim=(16384,))
    fn, args = graphs["philox_u32_65536"]
    a = aot.to_hlo_text(jax.jit(fn).lower(*args), return_tuple=False)
    b = aot.to_hlo_text(jax.jit(fn).lower(*args), return_tuple=False)
    assert a == b


def test_hlo_text_invariants():
    """The Rust loader needs parseable HLO text with an ENTRY computation
    and (for single-output graphs) a non-tuple root."""
    graphs = model.aot_graphs(sizes_block=(65536,), sizes_sim=(16384,))
    fn, args = graphs["brownian_step_16384"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args), return_tuple=False)
    assert "ENTRY" in text
    assert "f64[16384,4]" in text
    fn2, args2 = graphs["brownian_step_stateful_16384"]
    text2 = aot.to_hlo_text(jax.jit(fn2).lower(*args2), return_tuple=True)
    assert "ENTRY" in text2
    # Tuple wrapper present for the multi-output graph.
    assert "(f64[16384,4]" in text2.replace(" ", "")[:20000] or "tuple" in text2


@pytest.mark.slow
def test_aot_main_small_only(tmp_path):
    """Full aot run in --small-only mode into a temp dir; manifest must
    list every graph and reference existing files."""
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--small-only"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest) >= 10
    for e in manifest:
        assert (tmp_path / e["file"]).exists(), e
        assert e["tuple"] in (0, 1)
    # Line manifest agrees with the JSON one.
    lines = [l for l in (tmp_path / "manifest.txt").read_text().splitlines() if l]
    assert len(lines) == len(manifest)
    for line in lines:
        assert len(line.split("|")) == 5
