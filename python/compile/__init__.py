"""Build-time compile path for the OpenRAND reproduction.

Everything under this package runs ONCE at `make artifacts` and never on the
request path. We enable x64 so uint64 arithmetic (Squares key mixing, Philox
mul-hi-lo) is available inside jnp / Pallas-interpret kernels; every array in
this package specifies its dtype explicitly, so the changed defaults are
inert.
"""

import jax

jax.config.update("jax_enable_x64", True)
