"""L1 Pallas kernels + pure-jnp oracles for the OpenRAND CBRNG family."""

import jax

jax.config.update("jax_enable_x64", True)
