"""Pure-jnp oracles for every OpenRAND generator.

These are the correctness anchors for the whole stack:

* the Pallas kernels (`philox.py`, `threefry.py`, `squares.py`, `tyche.py`)
  must match them **bitwise** (pytest),
* the Rust `core/` engines must match them **bitwise** (cross-layer
  integration test via the AOT artifacts),
* the raw cores must match the Random123 known-answer vectors
  (`test_kat.py`).

Everything is vectorized over a leading axis of counter blocks so oracles
stay fast enough to sweep with hypothesis.
"""

import jax.numpy as jnp
import numpy as np

from . import common as cm

U32, U64 = cm.U32, cm.U64


# ---------------------------------------------------------------------------
# Raw cores (vectorized over leading axis)
# ---------------------------------------------------------------------------

def philox4x32(ctr, key, rounds: int = 10):
    """Philox4x32-R. ctr: (..., 4) u32, key: (..., 2) u32 -> (..., 4) u32."""
    c0, c1, c2, c3 = (ctr[..., i] for i in range(4))
    k0, k1 = key[..., 0], key[..., 1]
    for r in range(rounds):
        if r > 0:
            k0 = k0 + cm.PHILOX_W_0
            k1 = k1 + cm.PHILOX_W_1
        hi0, lo0 = cm.mulhilo32(jnp.asarray(cm.PHILOX_M4_0, U32), c0)
        hi1, lo1 = cm.mulhilo32(jnp.asarray(cm.PHILOX_M4_1, U32), c2)
        c0, c1, c2, c3 = hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0
    return jnp.stack([c0, c1, c2, c3], axis=-1)


def philox2x32(ctr, key, rounds: int = 10):
    """Philox2x32-R. ctr: (..., 2) u32, key: (...,) u32 -> (..., 2) u32."""
    c0, c1 = ctr[..., 0], ctr[..., 1]
    k0 = key
    for r in range(rounds):
        if r > 0:
            k0 = k0 + cm.PHILOX_W_0
        hi, lo = cm.mulhilo32(jnp.asarray(cm.PHILOX_M2_0, U32), c0)
        c0, c1 = hi ^ k0 ^ c1, lo
    return jnp.stack([c0, c1], axis=-1)


def threefry4x32(ctr, key, rounds: int = 20):
    """Threefry4x32-R. ctr/key: (..., 4) u32 -> (..., 4) u32."""
    ks4 = jnp.asarray(cm.SKEIN_PARITY, U32) ^ key[..., 0] ^ key[..., 1] ^ key[..., 2] ^ key[..., 3]
    ks = [key[..., 0], key[..., 1], key[..., 2], key[..., 3], ks4]
    x = [ctr[..., i] + ks[i] for i in range(4)]
    for r in range(rounds):
        r0, r1 = cm.THREEFRY_R4[r % 8]
        if r % 2 == 0:
            x[0] = x[0] + x[1]
            x[1] = cm.rotl32(x[1], r0) ^ x[0]
            x[2] = x[2] + x[3]
            x[3] = cm.rotl32(x[3], r1) ^ x[2]
        else:
            x[0] = x[0] + x[3]
            x[3] = cm.rotl32(x[3], r0) ^ x[0]
            x[2] = x[2] + x[1]
            x[1] = cm.rotl32(x[1], r1) ^ x[2]
        if (r + 1) % 4 == 0:
            q = (r + 1) // 4
            for i in range(4):
                x[i] = x[i] + ks[(q + i) % 5]
            x[3] = x[3] + jnp.asarray(np.uint32(q), U32)
    return jnp.stack(x, axis=-1)


def threefry2x32(ctr, key, rounds: int = 20):
    """Threefry2x32-R. ctr/key: (..., 2) u32 -> (..., 2) u32."""
    ks = [key[..., 0], key[..., 1], jnp.asarray(cm.SKEIN_PARITY, U32) ^ key[..., 0] ^ key[..., 1]]
    x0 = ctr[..., 0] + ks[0]
    x1 = ctr[..., 1] + ks[1]
    for r in range(rounds):
        x0 = x0 + x1
        x1 = cm.rotl32(x1, cm.THREEFRY_R2[r % 8]) ^ x0
        if (r + 1) % 4 == 0:
            q = (r + 1) // 4
            x0 = x0 + ks[q % 3]
            x1 = x1 + ks[(q + 1) % 3] + jnp.asarray(np.uint32(q), U32)
    return jnp.stack([x0, x1], axis=-1)


def squares32(ctr, key):
    """Squares (Widynski 2020, 4-round squares32). ctr,key: (...,) u64 -> (...,) u32."""
    ctr = ctr.astype(U64)
    key = key.astype(U64)
    x = ctr * key
    y = x
    z = y + key
    x = x * x + y
    x = (x >> np.uint64(32)) | (x << np.uint64(32))
    x = x * x + z
    x = (x >> np.uint64(32)) | (x << np.uint64(32))
    x = x * x + y
    x = (x >> np.uint64(32)) | (x << np.uint64(32))
    return ((x * x + z) >> np.uint64(32)).astype(U32)


def _tyche_mix(a, b, c, d):
    a = a + b
    d = cm.rotl32(d ^ a, 16)
    c = c + d
    b = cm.rotl32(b ^ c, 12)
    a = a + b
    d = cm.rotl32(d ^ a, 8)
    c = c + d
    b = cm.rotl32(b ^ c, 7)
    return a, b, c, d


def _tyche_mix_i(a, b, c, d):
    b = cm.rotl32(b, 32 - 7) ^ c
    c = c - d
    d = cm.rotl32(d, 32 - 8) ^ a
    a = a - b
    b = cm.rotl32(b, 32 - 12) ^ c
    c = c - d
    d = cm.rotl32(d, 32 - 16) ^ a
    a = a - b
    return a, b, c, d


def tyche_init(seed_lo, seed_hi, ctr, inverse: bool = False):
    """Tyche state init: 20 warm-up rounds. Inputs (...,) u32 -> 4x (...,) u32."""
    shape = jnp.shape(ctr)
    a = jnp.broadcast_to(jnp.asarray(seed_hi, U32), shape)
    b = jnp.broadcast_to(jnp.asarray(seed_lo, U32), shape)
    c = jnp.broadcast_to(jnp.asarray(cm.TYCHE_C, U32), shape)
    d = jnp.asarray(cm.TYCHE_D, U32) ^ jnp.asarray(ctr, U32)
    mix = _tyche_mix_i if inverse else _tyche_mix
    for _ in range(20):
        a, b, c, d = mix(a, b, c, d)
    return a, b, c, d


def tyche_stream(seed_lo, seed_hi, ctr, n: int, inverse: bool = False):
    """First n outputs of a Tyche (or Tyche-i) stream. Returns (..., n) u32."""
    a, b, c, d = tyche_init(seed_lo, seed_hi, ctr, inverse)
    mix = _tyche_mix_i if inverse else _tyche_mix
    outs = []
    for _ in range(n):
        a, b, c, d = mix(a, b, c, d)
        outs.append(a if inverse else b)
    return jnp.stack(outs, axis=-1)


# ---------------------------------------------------------------------------
# Canonical streams per the counter contract (common.py)
# ---------------------------------------------------------------------------

def philox4x32_stream(seed: int, ctr: int, n: int):
    """First n u32 words of the OpenRAND Philox4x32-10 stream (seed, ctr)."""
    lo, hi = cm.split_seed(seed)
    nblk = (n + 3) // 4
    j = jnp.arange(nblk, dtype=U32)
    blocks = jnp.stack(
        [j, jnp.full_like(j, np.uint32(ctr)), jnp.zeros_like(j), jnp.zeros_like(j)], axis=-1
    )
    key = jnp.broadcast_to(jnp.asarray([lo, hi], U32), (nblk, 2))
    return philox4x32(blocks, key).reshape(-1)[:n]


def philox2x32_stream(seed: int, ctr: int, n: int):
    lo, hi = cm.split_seed(seed)
    k = np.uint32((int(lo) ^ (int(hi) * 0x9E3779B9)) & 0xFFFF_FFFF)
    nblk = (n + 1) // 2
    j = jnp.arange(nblk, dtype=U32)
    blocks = jnp.stack([j, jnp.full_like(j, np.uint32(ctr))], axis=-1)
    key = jnp.full((nblk,), k, U32)
    return philox2x32(blocks, key).reshape(-1)[:n]


def threefry4x32_stream(seed: int, ctr: int, n: int):
    lo, hi = cm.split_seed(seed)
    nblk = (n + 3) // 4
    j = jnp.arange(nblk, dtype=U32)
    blocks = jnp.stack(
        [j, jnp.full_like(j, np.uint32(ctr)), jnp.zeros_like(j), jnp.zeros_like(j)], axis=-1
    )
    key = jnp.broadcast_to(jnp.asarray([lo, hi, np.uint32(0), np.uint32(0)], U32), (nblk, 4))
    return threefry4x32(blocks, key).reshape(-1)[:n]


def threefry2x32_stream(seed: int, ctr: int, n: int):
    lo, hi = cm.split_seed(seed)
    nblk = (n + 1) // 2
    j = jnp.arange(nblk, dtype=U32)
    blocks = jnp.stack([j, jnp.full_like(j, np.uint32(ctr))], axis=-1)
    key = jnp.broadcast_to(jnp.asarray([lo, hi], U32), (nblk, 2))
    return threefry2x32(blocks, key).reshape(-1)[:n]


def squares_stream(seed: int, ctr: int, n: int):
    key = jnp.full((n,), np.uint64(cm.squares_key(seed)), U64)
    j = jnp.arange(n, dtype=U64)
    c = jnp.asarray(np.uint64((int(ctr) & 0xFFFF_FFFF) << 32), U64) | j
    return squares32(c, key)


def tyche_stream_api(seed: int, ctr: int, n: int, inverse: bool = False):
    lo, hi = cm.split_seed(seed)
    out = tyche_stream(lo, hi, jnp.asarray(np.uint32(ctr), U32), n, inverse)
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# Distribution references (normative conversions; see rust/src/dist/)
# ---------------------------------------------------------------------------

def box_muller_pair(u1, u2):
    """Box-Muller on a `draw_double2` pair: (..., ) f64 uniforms ->
    ((...,) f64, (...,) f64) standard-normal cos/sin branches.

    The exact arithmetic of ``rust/src/dist/normal.rs::BoxMuller`` (and
    the device graphs): ``u1`` is clamped to 2^-53 before the log, the
    same guard the Rust side applies.
    """
    u1 = jnp.maximum(u1, jnp.float64(2.0**-53))
    r = jnp.sqrt(jnp.float64(-2.0) * jnp.log(u1))
    theta = jnp.float64(2.0 * np.pi) * u2
    return r * jnp.cos(theta), r * jnp.sin(theta)


def normal_f64_stream(seed: int, ctr: int, n: int):
    """First n standard normals of the OpenRAND stream (seed, ctr).

    Normal i consumes exactly Philox counter block i (words 4i..4i+4):
    u1 = f64(w0, w1), u2 = f64(w2, w3), output = the cosine branch —
    what ``BoxMuller::sample`` returns on the host and the
    ``normal_f64_*`` artifacts return on the device.
    """
    w = philox4x32_stream(seed, ctr, 4 * n).reshape(n, 4)
    u1 = cm.u32x2_to_f64(w[:, 0], w[:, 1])
    u2 = cm.u32x2_to_f64(w[:, 2], w[:, 3])
    return box_muller_pair(u1, u2)[0]


STREAMS = {
    "philox": philox4x32_stream,
    "philox2x32": philox2x32_stream,
    "threefry": threefry4x32_stream,
    "threefry2x32": threefry2x32_stream,
    "squares": squares_stream,
    "tyche": tyche_stream_api,
    "tyche_i": lambda s, c, n: tyche_stream_api(s, c, n, inverse=True),
}
