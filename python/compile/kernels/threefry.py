"""L1 Pallas kernels: Threefry4x32-20 and Threefry2x32-20 counter-mode blocks.

Explicit arithmetic, independent of ref.py (see philox.py header for the
testing rationale and the TPU mapping notes). Threefry is add/rotate/xor
only — no multiplies — so on hardware without fast 32x32->64 multiply it
is the preferred member of the family; the ablation bench compares it
against Philox on this host.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import common as cm

U32 = cm.U32
BLOCK = 1024


def _rotl(x, n):
    return (x << np.uint32(n)) | (x >> np.uint32(32 - n))


def _tf4_rounds(x0, x1, x2, x3, k0, k1, k2, k3, rounds):
    ks4 = jnp.asarray(cm.SKEIN_PARITY, U32) ^ k0 ^ k1 ^ k2 ^ k3
    ks = (k0, k1, k2, k3, ks4)
    x0, x1, x2, x3 = x0 + k0, x1 + k1, x2 + k2, x3 + k3
    for r in range(rounds):
        r0, r1 = cm.THREEFRY_R4[r % 8]
        if r % 2 == 0:
            x0 = x0 + x1
            x1 = _rotl(x1, r0) ^ x0
            x2 = x2 + x3
            x3 = _rotl(x3, r1) ^ x2
        else:
            x0 = x0 + x3
            x3 = _rotl(x3, r0) ^ x0
            x2 = x2 + x1
            x1 = _rotl(x1, r1) ^ x2
        if (r + 1) % 4 == 0:
            q = (r + 1) // 4
            x0 = x0 + ks[q % 5]
            x1 = x1 + ks[(q + 1) % 5]
            x2 = x2 + ks[(q + 2) % 5]
            x3 = x3 + ks[(q + 3) % 5] + jnp.asarray(np.uint32(q), U32)
    return x0, x1, x2, x3


def _tf4_block_kernel(params_ref, o_ref, *, rounds):
    # params: (4,) u32 = [seed_lo, seed_hi, ctr, unused]
    pid = pl.program_id(0).astype(U32)
    j = pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)
    k0 = jnp.broadcast_to(params_ref[0], (BLOCK,))
    k1 = jnp.broadcast_to(params_ref[1], (BLOCK,))
    c1 = jnp.broadcast_to(params_ref[2], (BLOCK,))
    z = jnp.zeros((BLOCK,), U32)
    x0, x1, x2, x3 = _tf4_rounds(j, c1, z, z, k0, k1, z, z, rounds)
    o_ref[...] = jnp.stack([x0, x1, x2, x3], axis=-1).reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "rounds"))
def threefry4x32_block(params, n: int, rounds: int = 20):
    """First `n` u32 words of the Threefry4x32-R stream. params=[seed_lo, seed_hi, ctr, 0]."""
    assert n % (4 * BLOCK) == 0, n
    grid = n // (4 * BLOCK)
    return pl.pallas_call(
        functools.partial(_tf4_block_kernel, rounds=rounds),
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((4 * BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(params)


def _tf4_block_at_kernel(params_ref, o_ref, *, rounds):
    # params: (4,) u32 = [seed_lo, seed_hi, ctr, base_block] — the offset
    # variant of `_tf4_block_kernel`: counter lane starts at base_block.
    pid = pl.program_id(0).astype(U32)
    j = params_ref[3] + pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)
    k0 = jnp.broadcast_to(params_ref[0], (BLOCK,))
    k1 = jnp.broadcast_to(params_ref[1], (BLOCK,))
    c1 = jnp.broadcast_to(params_ref[2], (BLOCK,))
    z = jnp.zeros((BLOCK,), U32)
    x0, x1, x2, x3 = _tf4_rounds(j, c1, z, z, k0, k1, z, z, rounds)
    o_ref[...] = jnp.stack([x0, x1, x2, x3], axis=-1).reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "rounds"))
def threefry4x32_block_at(params, n: int, rounds: int = 20):
    """Stream words `4*base .. 4*base + n` of the Threefry4x32-R stream.

    params: (4,) u32 `[seed_lo, seed_hi, ctr, base_block]`; base 0 is
    bitwise `threefry4x32_block`.
    """
    assert n % (4 * BLOCK) == 0, n
    grid = n // (4 * BLOCK)
    return pl.pallas_call(
        functools.partial(_tf4_block_at_kernel, rounds=rounds),
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((4 * BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(params)


def _tf2_block_kernel(params_ref, o_ref, *, rounds):
    # params: (4,) u32 = [seed_lo, seed_hi, ctr, unused]
    pid = pl.program_id(0).astype(U32)
    j = pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)
    k0 = jnp.broadcast_to(params_ref[0], (BLOCK,))
    k1 = jnp.broadcast_to(params_ref[1], (BLOCK,))
    ks2 = jnp.asarray(cm.SKEIN_PARITY, U32) ^ k0 ^ k1
    ks = (k0, k1, ks2)
    x0 = j + k0
    x1 = jnp.broadcast_to(params_ref[2], (BLOCK,)) + k1
    for r in range(rounds):
        x0 = x0 + x1
        x1 = _rotl(x1, cm.THREEFRY_R2[r % 8]) ^ x0
        if (r + 1) % 4 == 0:
            q = (r + 1) // 4
            x0 = x0 + ks[q % 3]
            x1 = x1 + ks[(q + 1) % 3] + jnp.asarray(np.uint32(q), U32)
    o_ref[...] = jnp.stack([x0, x1], axis=-1).reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "rounds"))
def threefry2x32_block(params, n: int, rounds: int = 20):
    """First `n` u32 words of the Threefry2x32-R stream. params=[seed_lo, seed_hi, ctr, 0]."""
    assert n % (2 * BLOCK) == 0, n
    grid = n // (2 * BLOCK)
    return pl.pallas_call(
        functools.partial(_tf2_block_kernel, rounds=rounds),
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((2 * BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(params)
