"""L1 Pallas kernel: Tyche / Tyche-i (Neves & Araujo 2011) lane-parallel block.

Tyche is not strictly counter-based — each (seed, ctr) stream is
sequential — so the parallel-block shape differs from the Philox family:
lane `i` of the output block is the FIRST output of stream
`(seed, ctr = base_ctr ^ i)`. That is exactly how the paper uses Tyche on
devices: one short stream per processing element per kernel launch.
`words` > 1 unrolls additional sequential outputs per lane, laid out
word-major *within each grid tile* of BLOCK lanes: word w of tile-local
lane l in tile g lands at `out[g*BLOCK*words + w*BLOCK + l]`. With
words=1 (the only layout the model layer uses) this is simply lane i at
`out[i]`.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from . import common as cm

U32 = cm.U32
BLOCK = 1024


def _mix(a, b, c, d):
    a = a + b
    d = cm.rotl32(d ^ a, 16)
    c = c + d
    b = cm.rotl32(b ^ c, 12)
    a = a + b
    d = cm.rotl32(d ^ a, 8)
    c = c + d
    b = cm.rotl32(b ^ c, 7)
    return a, b, c, d


def _mix_i(a, b, c, d):
    b = cm.rotl32(b, 32 - 7) ^ c
    c = c - d
    d = cm.rotl32(d, 32 - 8) ^ a
    a = a - b
    b = cm.rotl32(b, 32 - 12) ^ c
    c = c - d
    d = cm.rotl32(d, 32 - 16) ^ a
    a = a - b
    return a, b, c, d


def _tyche_block_kernel(params_ref, o_ref, *, words, inverse):
    # params: (4,) u32 = [seed_lo, seed_hi, base_ctr, unused]
    pid = pl.program_id(0).astype(U32)
    lane = pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)
    a = jnp.broadcast_to(params_ref[1], (BLOCK,))
    b = jnp.broadcast_to(params_ref[0], (BLOCK,))
    c = jnp.full((BLOCK,), cm.TYCHE_C, U32)
    d = jnp.asarray(cm.TYCHE_D, U32) ^ (params_ref[2] ^ lane)
    mix = _mix_i if inverse else _mix
    for _ in range(20):
        a, b, c, d = mix(a, b, c, d)
    outs = []
    for _ in range(words):
        a, b, c, d = mix(a, b, c, d)
        outs.append(a if inverse else b)
    o_ref[...] = jnp.stack(outs, axis=0).reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "inverse"))
def tyche_stream_block(params, n: int, inverse: bool = False):
    """Stream-ordered Tyche: words `base .. base + n` of ONE (seed, ctr) stream.

    params: (4,) u32 `[seed_lo, seed_hi, ctr, base_word]`. Unlike the
    lane-major `tyche_block` above (one stream per lane), this serves the
    single sequential stream the host engine produces — word `w` is the
    output of the `(20 + w + 1)`-th MIX after init — so it matches the
    `fill_u32` stream layout and the device backend can serve Tyche fills.

    A dependency chain of length `20 + base + n` cannot be expressed as a
    Pallas grid (there is no lane parallelism to map), so this graph is
    plain `lax` — it lowers to the same HLO-text artifact format either
    way: a fori_loop warm-up of `20 + base` mixes (dynamic trip count —
    the base is a runtime parameter) followed by a length-`n` scan
    emitting one word per mix.
    """
    mix = _mix_i if inverse else _mix
    a = jnp.broadcast_to(params[1], ())
    b = jnp.broadcast_to(params[0], ())
    c = jnp.asarray(cm.TYCHE_C, U32)
    d = jnp.asarray(cm.TYCHE_D, U32) ^ params[2]
    warmups = np.uint64(20) + params[3].astype(cm.U64)

    def warm(_i, s):
        return mix(*s)

    state = lax.fori_loop(np.uint64(0), warmups, warm, (a, b, c, d))

    def step(s, _):
        s = mix(*s)
        return s, (s[0] if inverse else s[1])

    _, out = lax.scan(step, state, None, length=n)
    return out


@functools.partial(jax.jit, static_argnames=("n", "words", "inverse"))
def tyche_block(params, n: int, words: int = 1, inverse: bool = False):
    """`n` u32 outputs: `n // words` lanes x `words` sequential outputs each.

    params: (4,) u32 `[seed_lo, seed_hi, base_ctr, 0]`; lane i uses
    ctr = base_ctr ^ i. Layout lanes-first per word (see module header).
    """
    assert n % (BLOCK * words) == 0, n
    grid = n // (BLOCK * words)
    return pl.pallas_call(
        functools.partial(_tyche_block_kernel, words=words, inverse=inverse),
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BLOCK * words,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(params)
