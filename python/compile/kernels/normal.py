"""L1 Pallas kernel: standard normals via Box-Muller over Philox words.

The normative normal of the stack (the device side of
``rust/src/dist/normal.rs::BoxMuller``). Stream discipline, shared with
`common.py`'s conversion contract and pinned by KATs on both layers:

* normal ``i`` consumes **exactly Philox4x32-10 counter block i** —
  stream words ``4i..4i+4`` of the stream ``(seed, ctr)``;
* ``u1 = f64(w0, w1)``, ``u2 = f64(w2, w3)`` (the `draw_double2` pair);
* ``z_i = sqrt(-2 ln max(u1, 2^-53)) * cos(2π u2)`` — the cosine branch,
  matching what ``BoxMuller::sample`` returns on the host. The sine
  branch is intentionally not emitted: keeping one output per counter
  block is what lets the host re-derive any block independently.

Like the other kernels, the arithmetic is written out inside the
pallas_call (sharing only the raw Philox rounds with `philox.py`), so
the pytest parity check against the `ref.py` oracle is a real
double-implementation test. `interpret=True` for the same reason as the
rest of L1: the CPU PJRT plugin cannot execute Mosaic custom-calls.

TPU mapping: BLOCK normals per grid step = BLOCK counter blocks; the
tile is VPU-bound (40 u32 multiplies + one ln/cos pair per 8 output
bytes), f64 tile footprint BLOCK*8 B = 8 KiB for BLOCK=1024 — far under
VMEM.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import common as cm
from .philox import BLOCK, _philox4_rounds

U32 = cm.U32


def _normal_block_kernel(params_ref, o_ref, *, rounds):
    # params: (4,) u32 = [seed_lo, seed_hi, ctr, unused]
    pid = pl.program_id(0).astype(U32)
    j = pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)
    k0 = jnp.broadcast_to(params_ref[0], (BLOCK,))
    k1 = jnp.broadcast_to(params_ref[1], (BLOCK,))
    c1 = jnp.broadcast_to(params_ref[2], (BLOCK,))
    z = jnp.zeros((BLOCK,), U32)
    w0, w1, w2, w3 = _philox4_rounds(j, c1, z, z, k0, k1, rounds)
    u1 = jnp.maximum(cm.u32x2_to_f64(w0, w1), jnp.float64(2.0**-53))
    u2 = cm.u32x2_to_f64(w2, w3)
    r = jnp.sqrt(jnp.float64(-2.0) * jnp.log(u1))
    o_ref[...] = r * jnp.cos(jnp.float64(2.0 * np.pi) * u2)


@functools.partial(jax.jit, static_argnames=("n", "rounds"))
def normal_block(params, n: int, rounds: int = 10):
    """First `n` standard normals of the stream described by `params`.

    params: (4,) u32 `[seed_lo, seed_hi, ctr, 0]`; `n` must be a
    multiple of BLOCK. Consumes stream words 0..4n (one counter block
    per normal).
    """
    assert n % BLOCK == 0, n
    grid = n // BLOCK
    return pl.pallas_call(
        functools.partial(_normal_block_kernel, rounds=rounds),
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float64),
        interpret=True,
    )(params)
