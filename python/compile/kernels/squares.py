"""L1 Pallas kernel: Squares (Widynski 2020) counter-mode block.

Squares is the smallest-state member of the family (64-bit key + 64-bit
counter) and the fastest on CPUs; the paper's Fig. 4a shows it leading
the field at long stream lengths. The kernel needs genuine u64 arithmetic
(x64 is enabled package-wide); on real TPU this would be emulated via
32-bit pairs — see DESIGN.md §Hardware-Adaptation.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import common as cm

U32, U64 = cm.U32, cm.U64
BLOCK = 1024


def _squares_block_kernel(params_ref, o_ref):
    # params: (4,) u32 = [key_lo, key_hi, ctr, unused]
    pid = pl.program_id(0).astype(U32)
    j = (pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)).astype(U64)
    key = (params_ref[1].astype(U64) << np.uint64(32)) | params_ref[0].astype(U64)
    key = jnp.broadcast_to(key, (BLOCK,))
    ctr = (params_ref[2].astype(U64) << np.uint64(32)) | j
    x = ctr * key
    y = x
    z = y + key
    x = x * x + y
    x = (x >> np.uint64(32)) | (x << np.uint64(32))
    x = x * x + z
    x = (x >> np.uint64(32)) | (x << np.uint64(32))
    x = x * x + y
    x = (x >> np.uint64(32)) | (x << np.uint64(32))
    o_ref[...] = ((x * x + z) >> np.uint64(32)).astype(U32)


def _squares_block_at_kernel(params_ref, o_ref):
    # params: (4,) u32 = [key_lo, key_hi, ctr, base_word] — the offset
    # variant: word index starts at base_word. The u32 add wraps, which
    # is exactly the engine's 2^32-word stream period.
    pid = pl.program_id(0).astype(U32)
    j = (params_ref[3] + pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)).astype(U64)
    key = (params_ref[1].astype(U64) << np.uint64(32)) | params_ref[0].astype(U64)
    key = jnp.broadcast_to(key, (BLOCK,))
    ctr = (params_ref[2].astype(U64) << np.uint64(32)) | j
    x = ctr * key
    y = x
    z = y + key
    x = x * x + y
    x = (x >> np.uint64(32)) | (x << np.uint64(32))
    x = x * x + z
    x = (x >> np.uint64(32)) | (x << np.uint64(32))
    x = x * x + y
    x = (x >> np.uint64(32)) | (x << np.uint64(32))
    o_ref[...] = ((x * x + z) >> np.uint64(32)).astype(U32)


@functools.partial(jax.jit, static_argnames=("n",))
def squares_block_at(params, n: int):
    """Stream words `base .. base + n` of the Squares stream.

    params: (4,) u32 `[key_lo, key_hi, ctr, base_word]` (Squares emits one
    word per counter, so the base is a word index); base 0 is bitwise
    `squares_block`.
    """
    assert n % BLOCK == 0, n
    grid = n // BLOCK
    return pl.pallas_call(
        _squares_block_at_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(params)


@functools.partial(jax.jit, static_argnames=("n",))
def squares_block(params, n: int):
    """First `n` u32 outputs of the Squares stream.

    params: (4,) u32 `[key_lo, key_hi, ctr, 0]` where key = squares_key(seed)
    (the splitmix64 derivation happens host-side; see common.squares_key).
    """
    assert n % BLOCK == 0, n
    grid = n // BLOCK
    return pl.pallas_call(
        _squares_block_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(params)
