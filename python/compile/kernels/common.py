"""Counter/stream layout contract — shared, bit-exact, with `rust/src/core/counter.rs`.

This module is one of the two normative definitions of how an OpenRAND
stream `(seed: u64, ctr: u32)` maps onto raw CBRNG invocations.  The other
is `rust/src/core/counter.rs`; the integration test `cross_layer.rs` and
`python/tests/test_kat.py` hold them bit-identical.

Contract (documented identically on the Rust side):

* ``seed_lo = seed & 0xffff_ffff``, ``seed_hi = seed >> 32``.
* **Philox4x32-10** — key ``[seed_lo, seed_hi]``; block ``j`` (yielding
  output words ``4j..4j+4`` of the stream) uses counter
  ``[j, ctr, 0, 0]``.
* **Philox2x32-10** — key ``seed_lo ^ (seed_hi * 0x9E3779B9 mod 2^32)``;
  block ``j`` (words ``2j..2j+2``) uses counter ``[j, ctr]``.
* **Threefry4x32-20** — key ``[seed_lo, seed_hi, 0, 0]``; counter
  ``[j, ctr, 0, 0]``.
* **Threefry2x32-20** — key ``[seed_lo, seed_hi]``; counter ``[j, ctr]``.
* **Squares32** — key ``splitmix64(seed) | 1`` (odd, well-mixed); output
  word ``j`` uses the 64-bit counter ``(ctr << 32) | j``.
* **Tyche / Tyche-i** — not strictly counter-based: state seeded as
  ``a = seed_hi, b = seed_lo, c = 2654435769, d = 1367130551 ^ ctr`` then
  20 warm-up MIX rounds; word ``j`` is produced by the ``j``-th subsequent
  MIX (sequential access only).

Stream-to-uniform conversions (also normative):

* ``f32 in [0,1)`` : ``(u32 >> 8) * 2^-24``
* ``f64 in [0,1)`` : ``(((hi as u64) << 32 | lo) >> 11) * 2^-53`` where
  ``hi`` is stream word ``2m`` and ``lo`` is word ``2m+1``.
"""

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
U64 = jnp.uint64

# Philox constants (Salmon et al., SC'11).
PHILOX_M4_0 = np.uint32(0xD2511F53)
PHILOX_M4_1 = np.uint32(0xCD9E8D57)
PHILOX_M2_0 = np.uint32(0xD256D193)
PHILOX_W_0 = np.uint32(0x9E3779B9)  # golden ratio
PHILOX_W_1 = np.uint32(0xBB67AE85)  # sqrt(3) - 1

# Threefry (Skein) constants.
SKEIN_PARITY = np.uint32(0x1BD11BDA)
THREEFRY_R4 = ((10, 26), (11, 21), (13, 27), (23, 5), (6, 20), (17, 11), (25, 10), (18, 20))
THREEFRY_R2 = (13, 15, 26, 6, 17, 29, 16, 24)

# Tyche init constants (Neves & Araujo, PPAM'11).
TYCHE_C = np.uint32(2654435769)
TYCHE_D = np.uint32(1367130551)


def split_seed(seed: int):
    """64-bit python-int seed -> (lo, hi) numpy u32 pair."""
    seed = int(seed) & 0xFFFF_FFFF_FFFF_FFFF
    return np.uint32(seed & 0xFFFF_FFFF), np.uint32(seed >> 32)


def splitmix64(x: int) -> int:
    """Reference splitmix64 (python ints) — the Squares key-mixing function."""
    x = (int(x) + 0x9E3779B97F4A7C15) & 0xFFFF_FFFF_FFFF_FFFF
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFF_FFFF_FFFF_FFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFF_FFFF_FFFF_FFFF
    return z ^ (z >> 31)


def squares_key(seed: int) -> int:
    """Normative Squares key derivation: splitmix64(seed) | 1 (odd)."""
    return splitmix64(seed) | 1


# ---------------------------------------------------------------------------
# StreamKey derivation (hierarchical stream addressing) — shared bit-exactly
# with ``rust/src/stream/mod.rs``. A stream key is a (seed: u64, ctr: u32)
# pair reached structurally: root(s) = (s, 0); epoch(t) sets ctr = t
# (absolute, last wins); child(id) derives a fresh seed via the normative
# mix below and resets ctr to 0. ``python/tests/test_stream_keys.py`` and
# the Rust doctests pin the same literals on both layers.
# ---------------------------------------------------------------------------

#: Domain-separation tag of the child derivation (ASCII "chld").
STREAMKEY_DOMAIN_CHILD = 0x63686C64


def derive_child_seed(parent_seed: int, parent_ctr: int, child_id: int) -> int:
    """Normative child-key mix — the single 64 -> (seed, ctr) function.

    ``tag = (parent_ctr << 32) | STREAMKEY_DOMAIN_CHILD``;
    ``child_seed = splitmix64(splitmix64(splitmix64(parent_seed) ^ tag) ^ id)``;
    the child's counter is 0. For a fixed parent the map id -> seed is a
    bijection (xor + the splitmix64 permutation), so distinct child ids
    are guaranteed distinct seeds.
    """
    m64 = 0xFFFF_FFFF_FFFF_FFFF
    tag = ((int(parent_ctr) & 0xFFFF_FFFF) << 32) | STREAMKEY_DOMAIN_CHILD
    h = splitmix64(int(parent_seed) & m64)
    h = splitmix64(h ^ tag)
    return splitmix64(h ^ (int(child_id) & m64))


def stream_key_path(spec: str):
    """Parse the CLI key-path spelling ``SEED[/cID|/eT]...`` to (seed, ctr).

    Mirrors ``StreamKey::parse_path`` in rust/src/stream/mod.rs: a root
    seed (decimal or 0x hex) followed by c-prefixed child derivations and
    e-prefixed absolute epochs, applied left to right. ``7/c3/e1`` is
    root(7).child(3).epoch(1); ``7/e1`` is the legacy (seed=7, ctr=1).
    """

    def as_int(s: str, what: str) -> int:
        # Match Rust's u64 parse: no sign, no underscores, no overflow
        # (python's int() is laxer on all three).
        s = s.strip()
        try:
            if "_" in s or s.startswith(("-", "+")):
                raise ValueError(s)
            v = int(s, 16) if s.startswith("0x") else int(s)
        except ValueError as e:
            raise ValueError(f"bad {what} {s!r}") from e
        if v > 0xFFFF_FFFF_FFFF_FFFF:
            raise ValueError(f"bad {what} {s!r} (exceeds u64)")
        return v

    parts = spec.split("/")
    if not parts or not parts[0]:
        raise ValueError("empty key path (expected 'SEED[/cID|/eT]...')")
    seed, ctr = as_int(parts[0], "root seed"), 0
    for seg in parts[1:]:
        if seg.startswith("c"):
            seed, ctr = derive_child_seed(seed, ctr, as_int(seg[1:], "child id")), 0
        elif seg.startswith("e"):
            t = as_int(seg[1:], "epoch")
            if t > 0xFFFF_FFFF:
                raise ValueError(f"epoch {seg!r} exceeds the 32-bit counter")
            ctr = t
        else:
            raise ValueError(f"bad key segment {seg!r} (expected cID or eT)")
    return seed, ctr


def mulhilo32(a, b):
    """(hi, lo) 32-bit halves of the 64-bit product a*b (u32 inputs)."""
    prod = a.astype(U64) * b.astype(U64)
    return (prod >> np.uint64(32)).astype(U32), prod.astype(U32)


def rotl32(x, n: int):
    n = int(n)
    return (x << np.uint32(n)) | (x >> np.uint32(32 - n))


def u32_to_f32(u):
    """u32 -> f32 uniform in [0, 1) — top 24 bits."""
    return (u >> np.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def u32x2_to_f64(hi, lo):
    """two u32 stream words -> f64 uniform in [0, 1) — top 53 bits."""
    u = (hi.astype(U64) << np.uint64(32)) | lo.astype(U64)
    return (u >> np.uint64(11)).astype(jnp.float64) * jnp.float64(2.0**-53)
