"""Counter/stream layout contract — shared, bit-exact, with `rust/src/core/counter.rs`.

This module is one of the two normative definitions of how an OpenRAND
stream `(seed: u64, ctr: u32)` maps onto raw CBRNG invocations.  The other
is `rust/src/core/counter.rs`; the integration test `cross_layer.rs` and
`python/tests/test_kat.py` hold them bit-identical.

Contract (documented identically on the Rust side):

* ``seed_lo = seed & 0xffff_ffff``, ``seed_hi = seed >> 32``.
* **Philox4x32-10** — key ``[seed_lo, seed_hi]``; block ``j`` (yielding
  output words ``4j..4j+4`` of the stream) uses counter
  ``[j, ctr, 0, 0]``.
* **Philox2x32-10** — key ``seed_lo ^ (seed_hi * 0x9E3779B9 mod 2^32)``;
  block ``j`` (words ``2j..2j+2``) uses counter ``[j, ctr]``.
* **Threefry4x32-20** — key ``[seed_lo, seed_hi, 0, 0]``; counter
  ``[j, ctr, 0, 0]``.
* **Threefry2x32-20** — key ``[seed_lo, seed_hi]``; counter ``[j, ctr]``.
* **Squares32** — key ``splitmix64(seed) | 1`` (odd, well-mixed); output
  word ``j`` uses the 64-bit counter ``(ctr << 32) | j``.
* **Tyche / Tyche-i** — not strictly counter-based: state seeded as
  ``a = seed_hi, b = seed_lo, c = 2654435769, d = 1367130551 ^ ctr`` then
  20 warm-up MIX rounds; word ``j`` is produced by the ``j``-th subsequent
  MIX (sequential access only).

Stream-to-uniform conversions (also normative):

* ``f32 in [0,1)`` : ``(u32 >> 8) * 2^-24``
* ``f64 in [0,1)`` : ``(((hi as u64) << 32 | lo) >> 11) * 2^-53`` where
  ``hi`` is stream word ``2m`` and ``lo`` is word ``2m+1``.
"""

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
U64 = jnp.uint64

# Philox constants (Salmon et al., SC'11).
PHILOX_M4_0 = np.uint32(0xD2511F53)
PHILOX_M4_1 = np.uint32(0xCD9E8D57)
PHILOX_M2_0 = np.uint32(0xD256D193)
PHILOX_W_0 = np.uint32(0x9E3779B9)  # golden ratio
PHILOX_W_1 = np.uint32(0xBB67AE85)  # sqrt(3) - 1

# Threefry (Skein) constants.
SKEIN_PARITY = np.uint32(0x1BD11BDA)
THREEFRY_R4 = ((10, 26), (11, 21), (13, 27), (23, 5), (6, 20), (17, 11), (25, 10), (18, 20))
THREEFRY_R2 = (13, 15, 26, 6, 17, 29, 16, 24)

# Tyche init constants (Neves & Araujo, PPAM'11).
TYCHE_C = np.uint32(2654435769)
TYCHE_D = np.uint32(1367130551)


def split_seed(seed: int):
    """64-bit python-int seed -> (lo, hi) numpy u32 pair."""
    seed = int(seed) & 0xFFFF_FFFF_FFFF_FFFF
    return np.uint32(seed & 0xFFFF_FFFF), np.uint32(seed >> 32)


def splitmix64(x: int) -> int:
    """Reference splitmix64 (python ints) — the Squares key-mixing function."""
    x = (int(x) + 0x9E3779B97F4A7C15) & 0xFFFF_FFFF_FFFF_FFFF
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFF_FFFF_FFFF_FFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFF_FFFF_FFFF_FFFF
    return z ^ (z >> 31)


def squares_key(seed: int) -> int:
    """Normative Squares key derivation: splitmix64(seed) | 1 (odd)."""
    return splitmix64(seed) | 1


def mulhilo32(a, b):
    """(hi, lo) 32-bit halves of the 64-bit product a*b (u32 inputs)."""
    prod = a.astype(U64) * b.astype(U64)
    return (prod >> np.uint64(32)).astype(U32), prod.astype(U32)


def rotl32(x, n: int):
    n = int(n)
    return (x << np.uint32(n)) | (x >> np.uint32(32 - n))


def u32_to_f32(u):
    """u32 -> f32 uniform in [0, 1) — top 24 bits."""
    return (u >> np.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def u32x2_to_f64(hi, lo):
    """two u32 stream words -> f64 uniform in [0, 1) — top 53 bits."""
    u = (hi.astype(U64) << np.uint64(32)) | lo.astype(U64)
    return (u >> np.uint64(11)).astype(jnp.float64) * jnp.float64(2.0**-53)
