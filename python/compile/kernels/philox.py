"""L1 Pallas kernels: Philox4x32-10 and Philox2x32-10 counter-mode blocks.

The kernel arithmetic is written out explicitly (independently of
`ref.py`) so the pytest bitwise comparison between the two is a real
double-implementation check, mirroring how the Rust engines are verified.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid axis is the
HBM↔VMEM schedule the paper expressed with CUDA threadblocks. Each grid
step materializes `BLOCK` counter blocks *from the lane index alone* —
there is no state input, which is exactly the paper's "no state
management" property. Tile footprint: BLOCK×4 u32 out = 16 KiB for
BLOCK=1024, far under VMEM; the kernel is integer-ALU bound (40 u32
multiplies per 16 output bytes), MXU intentionally unused.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import common as cm

U32 = cm.U32
BLOCK = 1024  # counter blocks per grid step (=> 4*BLOCK u32 words per tile)


def _mulhilo(m, x):
    prod = m.astype(cm.U64) * x.astype(cm.U64)
    return (prod >> np.uint64(32)).astype(U32), prod.astype(U32)


def _philox4_rounds(c0, c1, c2, c3, k0, k1, rounds):
    m0 = jnp.asarray(cm.PHILOX_M4_0, U32)
    m1 = jnp.asarray(cm.PHILOX_M4_1, U32)
    for r in range(rounds):
        if r > 0:
            k0 = k0 + cm.PHILOX_W_0
            k1 = k1 + cm.PHILOX_W_1
        hi0, lo0 = _mulhilo(m0, c0)
        hi1, lo1 = _mulhilo(m1, c2)
        c0, c1, c2, c3 = hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0
    return c0, c1, c2, c3


def _philox4_block_kernel(params_ref, o_ref, *, rounds):
    # params: (4,) u32 = [seed_lo, seed_hi, ctr, unused]
    pid = pl.program_id(0).astype(U32)
    j = pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)
    k0 = jnp.broadcast_to(params_ref[0], (BLOCK,))
    k1 = jnp.broadcast_to(params_ref[1], (BLOCK,))
    c1 = jnp.broadcast_to(params_ref[2], (BLOCK,))
    z = jnp.zeros((BLOCK,), U32)
    c0, c1, c2, c3 = _philox4_rounds(j, c1, z, z, k0, k1, rounds)
    # stream order: block j contributes words 4j..4j+3
    o_ref[...] = jnp.stack([c0, c1, c2, c3], axis=-1).reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "rounds"))
def philox4x32_block(params, n: int, rounds: int = 10):
    """First `n` u32 words of the Philox4x32-R stream described by `params`.

    params: (4,) u32 `[seed_lo, seed_hi, ctr, 0]`. `n` must be a multiple
    of 4*BLOCK (the model layer pads and slices).
    """
    assert n % (4 * BLOCK) == 0, n
    grid = n // (4 * BLOCK)
    return pl.pallas_call(
        functools.partial(_philox4_block_kernel, rounds=rounds),
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((4 * BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(params)


def _philox4_block_at_kernel(params_ref, o_ref, *, rounds):
    # params: (4,) u32 = [seed_lo, seed_hi, ctr, base_block]
    #
    # Identical to `_philox4_block_kernel` except the counter lane starts
    # at `base_block` instead of 0 — the formerly-unused 4th params word.
    # u32 addition wraps, matching the host engine's counter arithmetic.
    pid = pl.program_id(0).astype(U32)
    j = params_ref[3] + pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)
    k0 = jnp.broadcast_to(params_ref[0], (BLOCK,))
    k1 = jnp.broadcast_to(params_ref[1], (BLOCK,))
    c1 = jnp.broadcast_to(params_ref[2], (BLOCK,))
    z = jnp.zeros((BLOCK,), U32)
    c0, c1, c2, c3 = _philox4_rounds(j, c1, z, z, k0, k1, rounds)
    o_ref[...] = jnp.stack([c0, c1, c2, c3], axis=-1).reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "rounds"))
def philox4x32_block_at(params, n: int, rounds: int = 10):
    """Stream words `4*base .. 4*base + n` of the Philox4x32-R stream.

    params: (4,) u32 `[seed_lo, seed_hi, ctr, base_block]` — block index
    `base_block` contributes stream words `4*base_block..`. With base 0
    this is bitwise `philox4x32_block` (the prefix artifact).
    """
    assert n % (4 * BLOCK) == 0, n
    grid = n // (4 * BLOCK)
    return pl.pallas_call(
        functools.partial(_philox4_block_at_kernel, rounds=rounds),
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((4 * BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(params)


def _philox2_block_kernel(params_ref, o_ref, *, rounds):
    # params: (4,) u32 = [key, ctr, unused, unused]  (2x32 key is 1 word)
    pid = pl.program_id(0).astype(U32)
    c0 = pid * np.uint32(BLOCK) + jnp.arange(BLOCK, dtype=U32)
    k0 = jnp.broadcast_to(params_ref[0], (BLOCK,))
    c1 = jnp.broadcast_to(params_ref[1], (BLOCK,))
    m = jnp.asarray(cm.PHILOX_M2_0, U32)
    for r in range(rounds):
        if r > 0:
            k0 = k0 + cm.PHILOX_W_0
        hi, lo = _mulhilo(m, c0)
        c0, c1 = hi ^ k0 ^ c1, lo
    o_ref[...] = jnp.stack([c0, c1], axis=-1).reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "rounds"))
def philox2x32_block(params, n: int, rounds: int = 10):
    """First `n` u32 words of the Philox2x32-R stream. params=[key, ctr, 0, 0]."""
    assert n % (2 * BLOCK) == 0, n
    grid = n // (2 * BLOCK)
    return pl.pallas_call(
        functools.partial(_philox2_block_kernel, rounds=rounds),
        grid=(grid,),
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=pl.BlockSpec((2 * BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(params)


def philox4_double2_lanes(pid_lo, pid_hi, step, rounds: int = 10):
    """Per-lane draw_double2: block 0 of stream (seed=lane pid, ctr=step).

    pid_lo/pid_hi: (L,) u32 per-lane seed halves; step: scalar u32.
    Returns (r1, r2): two (L,) f64 uniforms in [0,1). This is the exact
    arithmetic of the paper's Fig.-1 kernel body, used by the brownian
    model and shared between the stateless and stateful step kernels.
    """
    z = jnp.zeros_like(pid_lo)
    c1 = jnp.broadcast_to(jnp.asarray(step, U32), pid_lo.shape)
    w0, w1, w2, w3 = _philox4_rounds(z, c1, z, z, pid_lo, pid_hi, rounds)
    return cm.u32x2_to_f64(w0, w1), cm.u32x2_to_f64(w2, w3)
