"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.

Run once via `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

We lower via stablehlo -> XlaComputation with ``return_tuple=True``; the
Rust runtime unwraps with ``to_tuple1`` (single-output graphs) or
``to_vec`` (multi-output).

The manifest is written twice: ``manifest.json`` for humans and
``manifest.txt`` in a trivial line format for the dependency-free Rust
parser (`rust/src/runtime/artifact.rs`):

    name|file|in=dtype[shape],...|out=dtype[shape],...
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered, return_tuple: bool) -> str:
    """Single-output graphs are lowered WITHOUT the tuple wrapper so the
    Rust runtime can chain their output PjRtBuffer straight into the next
    step's input (`execute_b`) with no host round-trip — the §Perf device
    optimization. Multi-output graphs keep the tuple."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _sig(avals) -> str:
    parts = []
    for a in avals:
        shape = ",".join(str(d) for d in a.shape)
        parts.append(f"{a.dtype}[{shape}]")
    return ";".join(parts)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--small-only", action="store_true",
                    help="lower only the small shapes (fast CI mode)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    if args.small_only:
        graphs = model.aot_graphs(sizes_block=(65536,), sizes_sim=(16384,))
    else:
        graphs = model.aot_graphs()

    manifest = []
    for name, (fn, example_args) in sorted(graphs.items()):
        lowered = jax.jit(fn).lower(*example_args)
        out_avals = jax.eval_shape(fn, *example_args)
        multi = isinstance(out_avals, (tuple, list))
        out_avals = out_avals if multi else (out_avals,)
        text = to_hlo_text(lowered, return_tuple=multi)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "inputs": _sig(example_args),
            "outputs": _sig(out_avals),
            "tuple": int(multi),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "hlo_bytes": len(text),
        }
        manifest.append(entry)
        print(f"  {name:34s} -> {fname} ({len(text) / 1024:.0f} KiB)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        for e in manifest:
            f.write(
                f"{e['name']}|{e['file']}|in={e['inputs']}|out={e['outputs']}|tuple={e['tuple']}\n"
            )
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
