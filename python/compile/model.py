"""L2: JAX compute graphs for the OpenRAND reproduction.

Device-side analogues of the paper's CUDA kernels, calling the L1 Pallas
kernels. Every function here is lowered ONCE by `aot.py` to HLO text and
executed from the Rust coordinator via PJRT — Python never touches the
request path.

Graphs:

* ``uniform_u32_block`` / ``uniform_f64_block`` / ``normal_f64_block`` —
  raw block generation for a chosen generator (the device half of the
  Fig. 4a-style micro measurements, and general-purpose device RNG for
  downstream users).
* ``brownian_step`` — one step of the paper's Brownian-dynamics
  macro-benchmark, **OpenRAND style**: stateless, the RNG stream is
  re-derived per particle from ``(seed = pid ^ global_seed, ctr = step)``
  exactly as in the paper's Fig. 1.
* ``brownian_step_stateful`` + ``curand_state_init`` — the **cuRAND
  analogue** (paper Fig. 2): a 64-byte-per-particle state tensor is
  loaded, used, updated and stored every step, and a separate init graph
  mirrors the dedicated ``curand_init`` kernel. Identical Philox core, so
  any performance difference is pure state traffic + API overhead.
* ``brownian_init`` — deterministic initial particle placement.

Particle layout: ``(N, 4) f64 = [x, y, vx, vy]`` (struct-of-rows; pid is
the row index, as in the paper where ``p.pid`` is assigned from the
launch index).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import common as cm
from .kernels import philox as kphilox
from .kernels import squares as ksquares
from .kernels import threefry as kthreefry
from .kernels import tyche as ktyche

U32, U64 = cm.U32, cm.U64

# Physics constants — match rust/src/sim/brownian.rs (normative pair).
GAMMA = 0.5
MASS = 1.0
DT = 0.01

BLOCK_FNS = {
    "philox": kphilox.philox4x32_block,
    "philox2x32": kphilox.philox2x32_block,
    "threefry": kthreefry.threefry4x32_block,
    "threefry2x32": kthreefry.threefry2x32_block,
    "squares": ksquares.squares_block,
    "tyche": ktyche.tyche_block,
}

# Offset (base-parameterized) variants: the formerly-unused 4th params
# word is the starting counter-block index (philox/threefry), the
# starting word index (squares), or the starting stream word (tyche,
# which is stream-ordered here, not lane-major — see kernels/tyche.py).
# With base 0 each is bitwise its prefix counterpart, which the pytest
# layer pins; the Rust scheduler uses these to serve interior shards.
AT_BLOCK_FNS = {
    "philox": kphilox.philox4x32_block_at,
    "threefry": kthreefry.threefry4x32_block_at,
    "squares": ksquares.squares_block_at,
    "tyche": ktyche.tyche_stream_block,
}


def uniform_u32_block(params, n: int, gen: str = "philox"):
    """(n,) u32 raw stream block for generator `gen` (see kernels/)."""
    return BLOCK_FNS[gen](params, n)


def uniform_u32_at_block(params, n: int, gen: str = "philox"):
    """(n,) u32 interior stream span for `gen`, starting at params[3]
    (block or word units per AT_BLOCK_FNS — the §4 offset-fill layout)."""
    return AT_BLOCK_FNS[gen](params, n)


def uniform_f64_block(params, n: int, gen: str = "philox"):
    """(n,) f64 uniforms in [0,1): pairs of u32 words -> 53-bit doubles."""
    u = uniform_u32_block(params, 2 * n, gen)
    w = u.reshape(n, 2)
    return cm.u32x2_to_f64(w[:, 0], w[:, 1])


def normal_f64_block(params, n: int, gen: str = "philox"):
    """(n,) f64 standard normals via Box-Muller on consecutive f64 pairs.

    Matches `rust/src/dist/normal.rs::BoxMuller` bit-for-all-practical
    (same formula; libm vs XLA trig may differ in the last ulp — the
    integration test uses a 1e-12 tolerance here, unlike the bitwise u32
    checks).
    """
    u = uniform_f64_block(params, 2 * n, gen).reshape(n, 2)
    # Guard u1=0 -> log(0): the [0,1) draw can be exactly 0; substitute the
    # smallest representable step, as the Rust side does.
    u1 = jnp.maximum(u[:, 0], 2.0**-53)
    u2 = u[:, 1]
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(2.0 * jnp.pi * u2)


def _pid_seed_halves(n: int, params):
    """Per-particle stream seed = pid ^ global_seed, split into u32 halves."""
    pid = jnp.arange(n, dtype=U64)
    gseed = (params[1].astype(U64) << np.uint64(32)) | params[0].astype(U64)
    seed = pid ^ gseed
    return seed.astype(U32), (seed >> np.uint64(32)).astype(U32)


def brownian_step(pos_vel, params, n: int):
    """One OpenRAND-style Brownian-dynamics step (paper Fig. 1 kernel).

    pos_vel: (n, 4) f64; params: (4,) u32 [gseed_lo, gseed_hi, step, 0].
    Returns the updated (n, 4) f64. Drag + uniform random kick on the
    velocity, then explicit-Euler position update.
    """
    x, y, vx, vy = (pos_vel[:, i] for i in range(4))
    # Drag force.
    vx = vx - (GAMMA / MASS) * vx * DT
    vy = vy - (GAMMA / MASS) * vy * DT
    # Random kick: draw_double2 from stream (seed=pid^gseed, ctr=step).
    lo, hi = _pid_seed_halves(n, params)
    r1, r2 = kphilox.philox4_double2_lanes(lo, hi, params[2])
    sqrt_dt = jnp.sqrt(jnp.float64(DT))
    vx = vx + (r1 * 2.0 - 1.0) * sqrt_dt
    vy = vy + (r2 * 2.0 - 1.0) * sqrt_dt
    # Position update.
    x = x + vx * DT
    y = y + vy * DT
    return jnp.stack([x, y, vx, vy], axis=-1)


def curand_state_init(params, n: int):
    """cuRAND-analogue init kernel: build the per-particle state tensor.

    (n, 16) u32 = 64 bytes/particle, matching the paper's reported
    ~64 MB per million particles: words 0-3 counter, 4-5 key, 6-9 output
    buffer, 10 buffer position, 11-15 padding (cuRAND's
    curandStatePhilox4_32_10_t is 64 B).
    params: (4,) u32 [gseed_lo, gseed_hi, 0, 0].
    """
    pid = jnp.arange(n, dtype=U32)
    z = jnp.zeros((n,), U32)
    cols = [
        pid,  # ctr.x = subsequence (as curand_init(seed, i, 0, ..))
        z, z, z,
        jnp.broadcast_to(params[0], (n,)),  # key = global seed
        jnp.broadcast_to(params[1], (n,)),
    ] + [z] * 10
    return jnp.stack(cols, axis=-1)


def brownian_step_stateful(pos_vel, state, n: int):
    """cuRAND-style step (paper Fig. 2): load state, draw, store state.

    state: (n, 16) u32 carried through HBM both ways every step — that
    round-trip is exactly the overhead the paper attributes to cuRAND.
    Same Philox4x32-10 core as `brownian_step`.
    """
    x, y, vx, vy = (pos_vel[:, i] for i in range(4))
    vx = vx - (GAMMA / MASS) * vx * DT
    vy = vy - (GAMMA / MASS) * vy * DT
    c0, c1, c2, c3 = (state[:, i] for i in range(4))
    k0, k1 = state[:, 4], state[:, 5]
    w0, w1, w2, w3 = kphilox._philox4_rounds(c0, c1, c2, c3, k0, k1, 10)
    r1 = cm.u32x2_to_f64(w0, w1)
    r2 = cm.u32x2_to_f64(w2, w3)
    sqrt_dt = jnp.sqrt(jnp.float64(DT))
    vx = vx + (r1 * 2.0 - 1.0) * sqrt_dt
    vy = vy + (r2 * 2.0 - 1.0) * sqrt_dt
    x = x + vx * DT
    y = y + vy * DT
    # 128-bit counter increment, then store the full 64 B back.
    one = jnp.ones_like(c0)
    nc0 = c0 + one
    carry0 = (nc0 == 0).astype(U32)
    nc1 = c1 + carry0
    carry1 = ((nc1 == 0) & (carry0 == 1)).astype(U32)
    nc2 = c2 + carry1
    carry2 = ((nc2 == 0) & (carry1 == 1)).astype(U32)
    nc3 = c3 + carry2
    new_state = jnp.concatenate(
        [
            jnp.stack([nc0, nc1, nc2, nc3, k0, k1, w0, w1, w2, w3], axis=-1),
            state[:, 10:],
        ],
        axis=-1,
    )
    return jnp.stack([x, y, vx, vy], axis=-1), new_state


def brownian_step_stateful_pos(pos_vel, state, n: int):
    """Split stateful step, positions half (single-output so the Rust
    runtime can buffer-chain it; see aot.to_hlo_text). Reads the full
    state tensor — the HBM traffic is identical to the combined graph."""
    return brownian_step_stateful(pos_vel, state, n)[0]


def curand_state_update(state, n: int):
    """Split stateful step, state half: the 128-bit counter increment +
    full 64 B store-back. The cuRAND out-buffer words (6..10) are left
    untouched (positions never depend on them; cuRAND's buffering is an
    implementation detail the split device path does not materialize)."""
    c0, c1, c2, c3 = (state[:, i] for i in range(4))
    one = jnp.ones_like(c0)
    nc0 = c0 + one
    carry0 = (nc0 == 0).astype(U32)
    nc1 = c1 + carry0
    carry1 = ((nc1 == 0) & (carry0 == 1)).astype(U32)
    nc2 = c2 + carry1
    carry2 = ((nc2 == 0) & (carry1 == 1)).astype(U32)
    nc3 = c3 + carry2
    return jnp.concatenate(
        [jnp.stack([nc0, nc1, nc2, nc3], axis=-1), state[:, 4:]], axis=-1
    )


def brownian_init(n: int):
    """Deterministic initial particle placement on a grid, zero velocity.

    Matches rust/src/sim/brownian.rs::init_particles (normative pair).
    """
    side = int(np.ceil(np.sqrt(n)))
    pid = jnp.arange(n, dtype=jnp.float64)
    gx = jnp.floor_divide(pid, side)
    gy = jnp.mod(pid, side)
    z = jnp.zeros((n,), jnp.float64)
    return jnp.stack([gx, gy, z, z], axis=-1)


# ---------------------------------------------------------------------------
# AOT entry points: name -> (fn, example args). Consumed by aot.py.
# ---------------------------------------------------------------------------

def aot_graphs(sizes_block=(65536, 1048576), sizes_sim=(16384, 1048576)):
    """All graphs to lower, with their example argument shapes."""
    p4 = jax.ShapeDtypeStruct((4,), U32)
    graphs = {}
    for n in sizes_block:
        for gen in ("philox", "threefry", "squares", "tyche"):
            graphs[f"{gen}_u32_{n}"] = (
                functools.partial(uniform_u32_block, n=n, gen=gen), (p4,))
        for gen in ("philox", "threefry", "squares", "tyche"):
            graphs[f"{gen}_u32_at_{n}"] = (
                functools.partial(uniform_u32_at_block, n=n, gen=gen), (p4,))
        graphs[f"philox_f64_{n // 2}"] = (
            functools.partial(uniform_f64_block, n=n // 2, gen="philox"), (p4,))
        graphs[f"normal_f64_{n // 2}"] = (
            functools.partial(normal_f64_block, n=n // 2, gen="philox"), (p4,))
    for n in sizes_sim:
        pv = jax.ShapeDtypeStruct((n, 4), jnp.float64)
        st = jax.ShapeDtypeStruct((n, 16), U32)
        graphs[f"brownian_step_{n}"] = (
            functools.partial(brownian_step, n=n), (pv, p4))
        graphs[f"brownian_step_stateful_{n}"] = (
            functools.partial(brownian_step_stateful, n=n), (pv, st))
        graphs[f"brownian_step_stateful_pos_{n}"] = (
            functools.partial(brownian_step_stateful_pos, n=n), (pv, st))
        graphs[f"curand_state_update_{n}"] = (
            functools.partial(curand_state_update, n=n), (st,))
        graphs[f"curand_state_init_{n}"] = (
            functools.partial(curand_state_init, n=n), (p4,))
        graphs[f"brownian_init_{n}"] = (
            functools.partial(brownian_init, n=n), ())
    return graphs
