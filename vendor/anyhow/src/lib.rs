//! Vendored subset of `anyhow`, just large enough for this workspace.
//!
//! The build container has no crates.io registry, so the error-handling
//! surface the repo uses is reimplemented here as a path dependency:
//! [`Error`], [`Result`], [`anyhow!`], [`bail!`], [`Context`], and
//! `Error::msg`. Error values carry a message plus an optional chain of
//! context strings; `{:#}` renders the chain in root-cause order, like
//! upstream anyhow's alternate formatting.

use std::fmt;

/// A string-backed error with a context chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable (usable as a function
    /// value: `.map_err(anyhow::Error::msg)`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, outermost first, `: `-joined.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

/// Convert any std error into an [`Error`] so `?` works on io/parse/...
/// results inside `anyhow::Result` functions.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to a `Result`'s error side.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("broke with code {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke with code 7");
    }

    #[test]
    fn context_chain_alternate_format() {
        let r: std::result::Result<(), String> = Err("root cause".to_string());
        let e = r.context("while frobbing").unwrap_err();
        assert_eq!(format!("{e}"), "while frobbing");
        assert_eq!(format!("{e:#}"), "while frobbing: root cause");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), String> = Err("inner".to_string());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<u32> {
            let v: u32 = "not a number".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn msg_as_function_value() {
        let r: std::result::Result<(), String> = Err("boom".to_string());
        let e = r.map_err(Error::msg).unwrap_err();
        assert_eq!(e.root_message(), "boom");
    }
}
