//! Compile-time stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The build container has no crates.io registry and no PJRT shared
//! library, so this crate mirrors exactly the API surface that
//! `rust/src/runtime/` consumes. Host-side marshalling (literal
//! construction, reshape, dtype-checked readback) is fully functional;
//! anything that would require a real PJRT backend — compiling an HLO
//! module or executing a loaded executable — returns a clean
//! [`Error`] that the runtime converts into "run with a real
//! xla_extension build" diagnostics. All artifact-dependent tests in the
//! workspace already skip when artifacts/executables are unavailable, so
//! the crate builds and the host-only test suite runs green offline.

use std::fmt;
use std::rc::Rc;

/// Stub error type; `Display` matches how the runtime reports it.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what} requires a real PJRT backend (xla_extension); this build uses the vendored stub"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold (the two the runtime marshals).
mod sealed {
    pub trait Sealed {}
    impl Sealed for u32 {}
    impl Sealed for f64 {}
}

/// Native element types supported by the stub (`u32`, `f64`).
pub trait NativeType: sealed::Sealed + Copy {
    fn from_repr(repr: &Repr) -> Option<Vec<Self>>
    where
        Self: Sized;
    fn into_repr(data: Vec<Self>) -> Repr
    where
        Self: Sized;
}

/// Untyped literal storage.
#[derive(Debug, Clone)]
pub enum Repr {
    U32(Vec<u32>),
    F64(Vec<f64>),
}

impl NativeType for u32 {
    fn from_repr(repr: &Repr) -> Option<Vec<u32>> {
        match repr {
            Repr::U32(v) => Some(v.clone()),
            Repr::F64(_) => None,
        }
    }

    fn into_repr(data: Vec<u32>) -> Repr {
        Repr::U32(data)
    }
}

impl NativeType for f64 {
    fn from_repr(repr: &Repr) -> Option<Vec<f64>> {
        match repr {
            Repr::F64(v) => Some(v.clone()),
            Repr::U32(_) => None,
        }
    }

    fn into_repr(data: Vec<f64>) -> Repr {
        Repr::F64(data)
    }
}

/// A host-side tensor literal: typed storage + dims.
#[derive(Debug, Clone)]
pub struct Literal {
    repr: Repr,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let dims = vec![data.len() as i64];
        Literal { repr: T::into_repr(data.to_vec()), dims }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let have: i64 = self.dims.iter().product();
        let want: i64 = dims.iter().product();
        if have != want {
            return Err(Error(format!("reshape: {have} elements into shape {dims:?}")));
        }
        Ok(Literal { repr: self.repr.clone(), dims: dims.to_vec() })
    }

    /// Read back as a host vector; dtype-checked.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_repr(&self.repr).ok_or_else(|| Error("to_vec: dtype mismatch".to_string()))
    }

    /// Unpack a tuple literal. The stub never produces tuples (execution
    /// is unavailable), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("tuple literal readback"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module handle. The stub only checks the file is readable;
/// the text is retained so a future real backend swap stays drop-in.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation handle built from a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle. Never constructible through the stub
/// (uploads require a backend), which keeps the chaining API honest.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("buffer readback"))
    }
}

/// Loaded executable handle; `execute*` always reports the stub.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute_b"))
    }
}

struct ClientInner;

/// PJRT client handle. `Rc`-based (not `Send`/`Sync`), matching the real
/// crate's thread-confinement that `runtime::client` documents.
#[derive(Clone)]
pub struct PjRtClient {
    _inner: Rc<ClientInner>,
}

impl PjRtClient {
    /// The CPU client constructs fine (cheap handle); only compilation
    /// and execution need the real backend.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _inner: Rc::new(ClientInner) })
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1u32, 2, 3, 4]);
        assert_eq!(l.to_vec::<u32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(l.to_vec::<f64>().is_err());
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn client_constructs_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert_eq!(c.device_count(), 1);
        let comp = XlaComputation { _private: () };
        let e = c.compile(&comp).unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
